"""The n-ary einsum front-end: parity with jnp.einsum, path-optimizer
correctness and cost ordering, per-step strategy/backend selection."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.einsum import (
    AUTO_OPTIMAL_LIMIT,
    contraction_path,
    parse_nary,
    xeinsum,
)
from repro.core.table2 import CASES

DIMS = {"m": 5, "n": 7, "p": 3, "q": 4, "k": 4, "r": 6,
        "a": 5, "b": 3, "c": 6, "d": 2, "e": 4, "f": 3,
        "i": 3, "j": 4, "l": 5, "s": 5, "t": 6}


def _ops(spec, seed=0):
    rng = np.random.default_rng(seed)
    lhs = spec.replace(" ", "").split("->")[0].split(",")
    return [
        jnp.asarray(rng.standard_normal([DIMS[m] for m in modes]), jnp.float32)
        for modes in lhs
    ]


def _check(spec, *, optimize="auto", strategy="auto", seed=0, atol=1e-4):
    ops = _ops(spec, seed)
    ref = jnp.einsum(spec, *ops)
    got = xeinsum(spec, *ops, optimize=optimize, strategy=strategy)
    assert got.shape == ref.shape, (spec, got.shape, ref.shape)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ref), rtol=1e-4, atol=atol,
        err_msg=f"{spec} optimize={optimize} strategy={strategy}",
    )


# ---------------------------------------------------------------- parsing
def test_parse_nary_explicit_and_implicit():
    assert parse_nary("ab,bc->ac") == (("ab", "bc"), "ac")
    assert parse_nary("ab,bc") == (("ab", "bc"), "ac")     # einsum convention
    assert parse_nary("ab,ab") == (("ab", "ab"), "")       # full contraction
    assert parse_nary("mnk,kr,ms->nrs") == (("mnk", "kr", "ms"), "nrs")


@pytest.mark.parametrize("bad", [
    "aab,bc->ac",          # trace
    "ab,bc->ad",           # output mode not produced
    "ab,bc->aa",           # repeated output mode
    "ab...,bc->ac",        # ellipsis
])
def test_parse_nary_rejects(bad):
    with pytest.raises((ValueError, NotImplementedError)):
        parse_nary(bad)


def test_unknown_optimize_mode_rejected_even_for_two_operands():
    A, B = jnp.zeros((2, 3)), jnp.zeros((3, 4))
    with pytest.raises(ValueError, match="optimize"):
        xeinsum("ab,bc->ac", A, B, optimize="optimla")
    with pytest.raises(ValueError, match="optimize"):
        contraction_path("ab,bc,cd->ad", (2, 3), (3, 4), (4, 5),
                         optimize="best")


def test_xeinsum_operand_count_and_dims_checked():
    A = jnp.zeros((2, 3))
    with pytest.raises(ValueError):
        xeinsum("ab,bc->ac", A)                     # too few operands
    with pytest.raises(ValueError):
        xeinsum("ab,bc->ac", A, jnp.zeros((4, 5)))  # b: 3 vs 4


# ------------------------------------------------- Table II through xeinsum
@pytest.mark.parametrize("label", sorted(CASES))
@pytest.mark.parametrize("strategy", ["auto", "batched"])
def test_table2_cases_match_einsum(label, strategy):
    """Every pairwise Table II case through the n-ary front-end."""
    _check(CASES[label].row_major(), strategy=strategy,
           seed=hash(label) % 2**31)


# ------------------------------------------------------- multi-operand chains
CHAINS = [
    "ijk,mi,nj,pk->mnp",       # Tucker reconstruction (4 operands)
    "mnp,mi,nj,pk->ijk",       # Tucker core (the HOOI projection)
    "r,mr,nr,pr->mnp",         # CP reconstruction with weights
    "mnp,nr,pr->mr",           # MTTKRP mode-1
    "mnp,mr,pr->nr",           # MTTKRP mode-2
    "ab,bc,cd->ad",            # matrix chain
    "ab,bc,cd,de,ef->af",      # 5-operand chain
    "bij,bjk,bkl->bil",        # shared batch mode through the whole chain
    "bsd,btd,bte->bse",        # (QKᵀ)V-style chain
    "ab,bc->c",                # sum-only free mode (a) on an input
    "ab,cd->abcd",             # pure outer product
    "ab,ab->",                 # full contraction to a scalar
    "a,ab,b->",                # bilinear form x·M·y
    "mnk,kr,ms->nrs",          # the docstring's headline example
]


@pytest.mark.parametrize("spec", CHAINS)
@pytest.mark.parametrize("optimize", ["naive", "greedy", "optimal"])
def test_chains_match_einsum(spec, optimize):
    _check(spec, optimize=optimize)


@pytest.mark.parametrize("spec", ["abc->cab", "ab->b", "abc->b"])
def test_single_operand(spec):
    _check(spec)


@pytest.mark.parametrize("spec", ["ijk,mi,nj,pk->mnp", "mnp,nr,pr->mr"])
def test_pallas_strategy_matches(spec):
    """strategy="pallas" runs every step on the TPU kernels (interpret)."""
    _check(spec, strategy="pallas")


@pytest.mark.parametrize("strategy", ["flatten", "batched", "direct",
                                      "conventional"])
def test_per_step_strategies_on_chain(strategy):
    """n-ary semantics soften "flatten" to flatten-where-possible; every
    other strategy is applied per step verbatim."""
    _check("ijk,mi,nj,pk->mnp", strategy=strategy)


def test_precomputed_path_reuse():
    ops = _ops("ab,bc,cd->ad")
    path = contraction_path("ab,bc,cd->ad", *ops, optimize="optimal")
    ref = jnp.einsum("ab,bc,cd->ad", *ops)
    got = xeinsum("ab,bc,cd->ad", *ops, optimize=path)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)
    with pytest.raises(ValueError):
        xeinsum("ab,bc,ce->ae", *_ops("ab,bc,ce->ae"), optimize=path)


# ---------------------------------------------------------- path optimizer
def test_optimizer_beats_naive_on_asymmetric_chain():
    """Thin–fat–thin chain: contracting (bc,cd) first is ~30x cheaper.
    a=64, b=2, c=64, d=2: naive pays 2·a·b·c + 2·a·c·d = 32k flops,
    the planned order pays 2·b·c·d + 2·a·b·d = 1k."""
    shapes = [(64, 2), (2, 64), (64, 2)]
    naive = contraction_path("ab,bc,cd->ad", *shapes, optimize="naive")
    for optimize in ("greedy", "optimal"):
        p = contraction_path("ab,bc,cd->ad", *shapes, optimize=optimize)
        assert p.total_flops < naive.total_flops, p.describe()
        # the cheap pair (operands 1 and 2) is contracted first
        assert {p.steps[0].lhs, p.steps[0].rhs} == {1, 2}, p.describe()


def test_optimal_never_costlier_than_greedy_or_naive():
    specs_shapes = [
        ("ijk,mi,nj,pk->mnp", [(4, 5, 6), (30, 4), (31, 5), (32, 6)]),
        ("mnp,nr,pr->mr", [(20, 21, 22), (21, 4), (22, 4)]),
        ("ab,bc,cd,de->ae", [(50, 2), (2, 50), (50, 2), (2, 50)]),
        ("bsd,btd,bte->bse", [(2, 40, 6), (2, 41, 6), (2, 41, 7)]),
    ]
    for spec, shapes in specs_shapes:
        flops = {
            opt: contraction_path(spec, *shapes, optimize=opt).total_flops
            for opt in ("naive", "greedy", "optimal")
        }
        assert flops["optimal"] <= flops["greedy"], (spec, flops)
        assert flops["optimal"] <= flops["naive"], (spec, flops)


def test_auto_uses_optimal_up_to_limit_then_greedy():
    small = contraction_path(
        "ab,bc,cd->ad", (4, 4), (4, 4), (4, 4), optimize="auto")
    assert small.optimize == "optimal"
    n = AUTO_OPTIMAL_LIMIT + 1
    spec = ",".join(chr(ord("a") + i) + chr(ord("a") + i + 1) for i in range(n))
    spec += f"->a{chr(ord('a') + n)}"
    shapes = [(3, 3)] * n
    big = contraction_path(spec, *shapes, optimize="auto")
    assert big.optimize == "greedy"


def test_path_steps_are_layout_aware():
    """Equal-flop orders are broken by plan quality: no step of the chosen
    Tucker-reconstruction path is exceptional (each admits a flattened or
    strided-batched evaluation)."""
    p = contraction_path(
        "ijk,mi,nj,pk->mnp", (10, 10, 10), (96, 10), (96, 10), (96, 10),
        optimize="optimal",
    )
    assert all(s.kind != "exceptional" for s in p.steps), p.describe()


def test_describe_mentions_every_step():
    p = contraction_path("ab,bc,cd->ad", (4, 4), (4, 4), (4, 4))
    text = p.describe()
    assert "step 1" in text and "step 2" in text and "flops=" in text


def test_sum_only_modes_reduced_before_planning():
    # 'q' appears once and not in the output: summed up front, so the
    # planned path never carries it.
    p = contraction_path("aq,ab->b", (3, 9), (3, 4))
    assert all("q" not in s.spec.spec_str() for s in p.steps)
    _check("aq,ab->b")


# -------------------------------------------- decomposition expressions
def test_tucker_reconstruction_matches_reference():
    rng = np.random.default_rng(7)
    G = jnp.asarray(rng.standard_normal((4, 4, 4)), jnp.float32)
    A = jnp.asarray(rng.standard_normal((12, 4)), jnp.float32)
    B = jnp.asarray(rng.standard_normal((13, 4)), jnp.float32)
    C = jnp.asarray(rng.standard_normal((14, 4)), jnp.float32)
    ref = jnp.einsum("ijk,mi,nj,pk->mnp", G, A, B, C)
    from repro.core.tucker import tucker_reconstruct

    got = tucker_reconstruct(G, (A, B, C))
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_cp_reconstruction_expression():
    _check("r,mr,nr,pr->mnp", optimize="optimal")
    _check("r,mr,nr,pr->mnp", optimize="greedy")
