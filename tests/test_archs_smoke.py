"""Per-architecture smoke tests: reduced same-family configs, one
forward/train step on CPU, shape + NaN assertions (assignment deliverable f).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.models.ssm import init_mamba, init_ssm_cache, mamba_decode_step, mamba_mixer
from repro.models.transformer import Model

pytestmark = pytest.mark.slow  # 10-arch sweep: the other multi-minute module


def _smoke_batch(cfg, key, B=2, S=32):
    kt, kf, kl = jax.random.split(key, 3)
    batch = {"tokens": jax.random.randint(kt, (B, S), 0, cfg.vocab_size)}
    if cfg.frontend is not None:
        n = S if cfg.frontend.kind == "audio" else cfg.frontend.n_positions
        batch["features"] = jax.random.normal(kf, (B, n, cfg.frontend.feature_dim))
        batch["labels"] = jax.random.randint(kl, (B, S), 0, cfg.vocab_size)
    return batch


@pytest.mark.parametrize("arch", list_archs())
def test_forward_shapes_and_no_nans(arch):
    cfg = get_config(arch, smoke=True)
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = _smoke_batch(cfg, jax.random.PRNGKey(1))
    logits, aux = m(params, batch)
    S = 32 + (cfg.frontend.n_positions if cfg.frontend and cfg.frontend.kind == "vision" else 0)
    assert logits.shape == (2, S, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())
    assert np.isfinite(float(aux["load_balance_loss"]))


@pytest.mark.parametrize("arch", list_archs())
def test_one_train_step(arch):
    """loss + grads finite; a gradient step changes the loss."""
    cfg = get_config(arch, smoke=True)
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = _smoke_batch(cfg, jax.random.PRNGKey(1))

    @jax.jit
    def step(p):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: m.loss(p, batch), has_aux=True
        )(p)
        new_p = jax.tree.map(lambda w, g: w - 0.1 * g.astype(w.dtype), p, grads)
        return loss, new_p, grads

    loss0, new_params, grads = step(params)
    assert np.isfinite(float(loss0))
    gnorm = jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
    )
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0
    loss1, _, _ = step(new_params)
    assert float(loss1) != float(loss0)


@pytest.mark.parametrize("arch", [a for a in list_archs()
                                  if not get_config(a).encoder_only])
def test_decode_step(arch):
    cfg = get_config(arch, smoke=True)
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    cache = m.init_cache(2, 64)
    tok = jnp.zeros((2, 1), jnp.int32)
    for _ in range(3):
        logits, cache = m.decode_step(params, cache, tok)
        tok = jnp.argmax(logits, -1)[:, None]
    assert logits.shape == (2, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())
    assert int(cache["length"]) == 3


def test_encoder_only_rejects_decode():
    cfg = get_config("hubert-xlarge", smoke=True)
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError):
        m.decode_step(params, m.init_cache(1, 8), jnp.zeros((1, 1), jnp.int32))


def test_prefill_then_decode_matches_full_forward():
    """Teacher-forced decode after prefill must equal the parallel forward."""
    cfg = get_config("internlm2-20b", smoke=True)
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 12), 0, cfg.vocab_size)
    full_logits, _ = m(params, {"tokens": toks})

    cache = m.init_cache(1, 32)
    pre_logits, cache = m.prefill(params, {"tokens": toks[:, :8]}, cache)
    np.testing.assert_allclose(
        np.asarray(pre_logits), np.asarray(full_logits[:, 7]), rtol=2e-3, atol=2e-3
    )
    logits = pre_logits
    for t in range(8, 12):
        logits, cache = m.decode_step(params, cache, toks[:, t : t + 1])
        np.testing.assert_allclose(
            np.asarray(logits), np.asarray(full_logits[:, t]), rtol=2e-3, atol=2e-3
        )


def test_ssd_chunked_matches_recurrent():
    """Mamba2 SSD chunked scan ≡ step-by-step recurrence (state-space
    duality — the identity making the paper's batched-GEMM form valid)."""
    cfg = get_config("mamba2-1.3b", smoke=True).with_(n_periods=1)
    p = init_mamba(jax.random.PRNGKey(0), cfg)
    B, L = 2, 48  # not a multiple of chunk=16 → exercises chunk fallback
    x = jax.random.normal(jax.random.PRNGKey(1), (B, L, cfg.d_model)) * 0.5
    y_chunk, _ = mamba_mixer(cfg, p, x)
    cache = init_ssm_cache(cfg, B, jnp.float32)
    ys = []
    for t in range(L):
        yt, cache = mamba_decode_step(cfg, p, x[:, t : t + 1], cache)
        ys.append(yt)
    y_rec = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_rec),
                               rtol=2e-4, atol=2e-4)


def test_gemma2_softcap_bounds_logits():
    cfg = get_config("gemma2-27b", smoke=True)
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    logits, _ = m(params, _smoke_batch(cfg, jax.random.PRNGKey(1)))
    assert float(jnp.max(jnp.abs(logits))) <= cfg.final_softcap + 1e-3


def test_param_count_sanity():
    """Full configs should land near their nameplate sizes."""
    approx = {
        "mamba2-1.3b": (1.3e9, 0.35),
        "internlm2-20b": (20e9, 0.25),
        "gemma2-27b": (27e9, 0.35),
        "granite-20b": (20e9, 0.35),
        "kimi-k2-1t-a32b": (1.0e12, 0.35),
    }
    for arch, (target, tol) in approx.items():
        n = get_config(arch).param_count()
        assert abs(n - target) / target < tol, (arch, n, target)
