"""Distribution layer tests.

Multi-device tests run in subprocesses so the host-platform device count
(which locks at first jax init) never leaks into the other tests.
"""

import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.compress import Int8Compressor, compress_bf16

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(code: str, devices: int = 8, timeout: int = 560) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=timeout,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


# ------------------------------------------------------------- compression
def test_bf16_compression_close():
    g = {"w": jnp.linspace(-3, 3, 1000)}
    c = compress_bf16(g)
    assert float(jnp.max(jnp.abs(c["w"] - g["w"]))) < 0.02


def test_int8_error_feedback_is_unbiased():
    """Accumulated quantized gradients track accumulated true gradients."""
    comp = Int8Compressor(block=64)
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.standard_normal(256), jnp.float32)
    params = {"w": jnp.zeros(256)}
    res = comp.init_residual(params)
    acc = jnp.zeros(256)
    for _ in range(50):
        deq, res = comp.compress({"w": g_true}, res)
        acc = acc + deq["w"]
    err = float(jnp.max(jnp.abs(acc / 50 - g_true)))
    assert err < 0.02, err  # residual feedback keeps the average unbiased


def test_int8_quantization_bounded_error():
    comp = Int8Compressor(block=32)
    x = jnp.asarray(np.random.default_rng(1).standard_normal((33, 7)), jnp.float32)
    q = comp._quant_dequant(x)
    scale = float(jnp.max(jnp.abs(x))) / 127
    assert float(jnp.max(jnp.abs(q - x))) <= scale + 1e-6


# ------------------------------------------------------------------ rules
def test_sharding_rules_dedup_and_missing_axes():
    code = """
    import jax
    from repro.distributed.sharding import ShardingRules
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    rules = ShardingRules(mesh)
    # pod axis absent on this mesh -> dropped; duplicate mesh axis -> dropped
    spec = rules.physical(("batch", "kv_seq", "kv_heads", None))
    print(spec)
    """
    out = run_py(code, devices=8)
    assert "PartitionSpec('data', 'model', None, None)" in out


def test_sharded_train_step_matches_single_device():
    """Same batch, same init: loss on a 2x4 mesh equals single-device loss."""
    code = """
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config
    from repro.distributed.sharding import ShardingRules, use_rules
    from repro.launch.shardings import (param_logical_axes, batch_logical_axes,
                                        tree_shardings)
    from repro.models.transformer import init_params, lm_loss

    cfg = get_config("qwen2-moe-a2.7b", smoke=True).with_(n_periods=2)
    params = init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, cfg.vocab_size)
    batch = {"tokens": toks}
    loss_1dev = jax.jit(lambda p, b: lm_loss(cfg, p, b)[0])(params, batch)

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    rules = ShardingRules(mesh)
    p_spec = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
    p_sh = tree_shardings(rules, param_logical_axes(p_spec), p_spec)
    b_sh = tree_shardings(rules, batch_logical_axes(batch),
                          jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), batch))
    params_s = jax.device_put(params, p_sh)
    batch_s = jax.device_put(batch, b_sh)
    with mesh, use_rules(rules):
        loss_mesh = jax.jit(lambda p, b: lm_loss(cfg, p, b)[0])(params_s, batch_s)
    print("SINGLE", float(loss_1dev), "MESH", float(loss_mesh))
    assert abs(float(loss_1dev) - float(loss_mesh)) < 2e-3, (loss_1dev, loss_mesh)
    """
    run_py(code, devices=8)


def test_pipeline_matches_sequential():
    """GPipe over a 4-stage axis == running the stages sequentially."""
    code = """
    import jax, jax.numpy as jnp, numpy as np
    from repro.distributed.pipeline import pipeline_forward
    mesh = jax.make_mesh((4,), ("pod",))
    n_stages, n_micro, micro, d = 4, 8, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(0), n_stages)
    Ws = jnp.stack([jax.random.normal(k, (d, d)) * 0.3 for k in ks])
    x = jax.random.normal(jax.random.PRNGKey(1), (n_micro, micro, d))

    def stage_fn(params, x, stage_idx):
        return jnp.tanh(x @ params["W"])

    y_pipe = pipeline_forward(mesh, stage_fn, {"W": Ws}, x, axis="pod")

    y_ref = x
    for s in range(n_stages):
        y_ref = jnp.tanh(y_ref @ Ws[s])
    np.testing.assert_allclose(np.asarray(y_pipe), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
    print("PIPELINE OK")
    """
    out = run_py(code, devices=4)
    assert "PIPELINE OK" in out


def test_elastic_restore_across_mesh_sizes(tmp_path):
    """Checkpoint written unsharded restores onto a different mesh shape."""
    code = f"""
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config
    from repro.distributed.sharding import ShardingRules
    from repro.launch.shardings import param_logical_axes, tree_shardings
    from repro.models.transformer import init_params
    from repro.training.checkpoint import save_checkpoint, restore_checkpoint

    cfg = get_config("internlm2-20b", smoke=True).with_(n_periods=1)
    params = init_params(jax.random.PRNGKey(0), cfg)
    save_checkpoint({str(tmp_path)!r}, 7, params)

    # restore onto a 2x2 mesh (as if rescaled from some other fleet size)
    mesh = jax.make_mesh((2, 2), ("data", "model"))
    rules = ShardingRules(mesh)
    p_spec = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
    shardings = tree_shardings(rules, param_logical_axes(p_spec), p_spec)
    restored, extra, step = restore_checkpoint(
        {str(tmp_path)!r}, None, params, shardings=shardings)
    assert step == 7
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    print("ELASTIC OK", jax.tree.leaves(restored)[0].sharding)
    """
    out = run_py(code, devices=4)
    assert "ELASTIC OK" in out


# ------------------------------------------------------------- dryrun (CI)
def test_dryrun_smoke_cell_compiles_on_512_devices():
    """A reduced config through the real dryrun path on the 16x16 mesh."""
    code = """
    from repro.launch import dryrun  # sets 512 host devices FIRST
    import repro.configs.registry as reg
    # monkeypatch get_config to the smoke config so the cell stays tiny
    full = reg.get_config
    dryrun.get_config = lambda a, **kw: full(a, smoke=True)
    rec = dryrun_rec = dryrun.dryrun_cell("minicpm-2b", "train_4k", verbose=False)
    assert rec["status"] == "ok", rec
    rec2 = dryrun.dryrun_cell("minicpm-2b", "train_4k", multi_pod=True, verbose=False)
    assert rec2["status"] == "ok", rec2
    assert rec2["mesh"] == "2x16x16"
    print("DRYRUN OK", rec["flops"], rec2["flops"])
    """
    out = run_py(code, devices=512)
    assert "DRYRUN OK" in out


def test_skip_cells_report_reasons():
    code = """
    from repro.launch import dryrun
    rec = dryrun.dryrun_cell("hubert-xlarge", "decode_32k")
    assert rec["status"] == "skipped" and "encoder-only" in rec["reason"], rec
    rec = dryrun.dryrun_cell("gemma2-27b", "long_500k")
    assert rec["status"] == "skipped" and "sub-quadratic" in rec["reason"], rec
    print("SKIPS OK")
    """
    out = run_py(code, devices=8)
    assert "SKIPS OK" in out
