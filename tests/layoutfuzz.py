"""Seeded layout-fuzz generators for the native-layout differential tier.

The native SB-GEMM's claim is *layout obliviousness*: any mode ordering,
any storage layout of the operands, one kernel, zero copies.  The
generators here exercise exactly the axes that claim can fail on:

* **spec shape** — fully permuted mode orders (including the paper's
  exceptional no-first-mode cases), degenerate specs with zero free
  modes on either side (matvec / outer-product / scalar shapes),
  Hadamard-style shared batch modes, rank 1–5 operands;
* **mode extents** — dims 1–6 *including size-1 modes*, so tile clamps
  and padded extents are hit constantly;
* **operand storage** — each operand is materialised through a random
  numpy *layout treatment*: a contiguous buffer, a strided slice of a
  larger buffer, a negative-stride (reversed-axis) view, a
  transposed-storage view, or a stride-0 broadcast of a collapsed axis.
  The logical values are identical either way; the treatment controls
  the memory the arrays arrive from.

Operands are **integer-valued float32** drawn from a small range: every
product and partial sum in these dims is exactly representable, so any
reduction order gives the bit-identical result — the differential tests
assert ``np.array_equal`` against ``jnp.einsum``, not allclose.  A
single flipped tile origin, dropped k-step, or mis-addressed mode shows
up as a hard bit difference, never hides inside a tolerance.

No hypothesis dependency: plain ``numpy.random.default_rng`` with fixed
seeds, so every failure is a deterministic repro (module shared by the
slow fuzz tier in ``test_differential.py`` and the always-on smoke in
``test_layout_smoke.py``).
"""

import numpy as np

from repro.core.notation import ContractionSpec

SEED = 20260801
LAYOUT_STREAM = 77_000  # rng stream offset: disjoint from the other tiers

#: storage-layout treatments an operand may arrive through.
TREATMENTS = ("plain", "slice", "reverse", "transpose", "broadcast")


def gen_layout_spec(rng) -> tuple[ContractionSpec, dict]:
    """One random valid pairwise spec, biased toward layout edge cases.

    Unlike ``gen_pairwise`` (orders 2–5, free modes on both sides), this
    generator admits rank-1 operands, zero free modes (degenerate
    planner paths), zero contracted modes (outer products), and size-1
    extents — the shapes the native kernel must absorb without a copy.
    """
    letters = "abcdefghij"
    while True:
        n_k = int(rng.integers(0, 3))    # contracted modes (0 = outer)
        n_b = int(rng.integers(0, 3))    # shared batch modes
        n_af = int(rng.integers(0, 3))   # A's free modes
        n_bf = int(rng.integers(0, 3))   # B's free modes
        ra, rb = n_af + n_k + n_b, n_bf + n_k + n_b
        rc = n_af + n_bf + n_b
        if not (1 <= ra <= 5 and 1 <= rb <= 5 and rc <= 5):
            continue
        ms = list(letters[: n_k + n_b + n_af + n_bf])
        k = ms[:n_k]
        b = ms[n_k:n_k + n_b]
        af = ms[n_k + n_b:n_k + n_b + n_af]
        bf = ms[n_k + n_b + n_af:]
        a_modes = "".join(rng.permutation(af + k + b))
        b_modes = "".join(rng.permutation(bf + k + b))
        c_modes = "".join(rng.permutation(af + bf + b))
        cs = ContractionSpec(a_modes, b_modes, c_modes)
        try:
            cs.validate()
        except ValueError:
            continue
        # dims 1..6 with size-1 modes common enough to matter
        dims = {m: int(rng.integers(1, 7)) for m in ms}
        return cs, dims


def int_values(rng, shape) -> np.ndarray:
    """Integer-valued f32 operand: exact under any reduction order."""
    return rng.integers(-4, 5, size=shape).astype(np.float32)


def apply_treatment(rng, shape, treatment: str) -> np.ndarray:
    """Materialise an operand of ``shape`` through a storage layout.

    Returns a numpy view whose *logical* shape is ``shape`` but whose
    backing memory follows the treatment (strided / reversed /
    transposed / broadcast).  ``plain`` is the contiguous control.
    """
    shape = tuple(shape)
    if treatment == "plain" or not shape:
        return int_values(rng, shape)
    if treatment == "slice":  # strided window of a larger buffer
        ax = int(rng.integers(0, len(shape)))
        big = list(shape)
        step = int(rng.integers(2, 4))
        big[ax] = shape[ax] * step + int(rng.integers(0, 3))
        buf = int_values(rng, big)
        idx = [slice(None)] * len(shape)
        idx[ax] = slice(0, shape[ax] * step, step)
        view = buf[tuple(idx)]
    elif treatment == "reverse":  # negative stride on one axis
        ax = int(rng.integers(0, len(shape)))
        buf = int_values(rng, shape)
        idx = [slice(None)] * len(shape)
        idx[ax] = slice(None, None, -1)
        view = buf[tuple(idx)]
    elif treatment == "transpose":  # stored under a permuted axis order
        perm = tuple(rng.permutation(len(shape)))
        stored = int_values(rng, [shape[p] for p in perm])
        view = stored.transpose(tuple(np.argsort(perm)))
    elif treatment == "broadcast":  # stride-0 axis (repeated values)
        ax = int(rng.integers(0, len(shape)))
        collapsed = list(shape)
        collapsed[ax] = 1
        buf = int_values(rng, collapsed)
        view = np.broadcast_to(buf, shape)
    else:
        raise ValueError(f"unknown treatment {treatment!r}")
    assert view.shape == shape
    return view


def gen_layout_case(i: int):
    """Case ``i`` of the seeded layout-fuzz stream.

    Returns ``(cs, dims, A, B, treatments)`` where ``A``/``B`` are numpy
    arrays (possibly non-contiguous views) of the operand shapes.
    """
    rng = np.random.default_rng([SEED, LAYOUT_STREAM + i])
    cs, dims = gen_layout_spec(rng)
    t_a = TREATMENTS[int(rng.integers(0, len(TREATMENTS)))]
    t_b = TREATMENTS[int(rng.integers(0, len(TREATMENTS)))]
    A = apply_treatment(rng, [dims[m] for m in cs.a_modes], t_a)
    B = apply_treatment(rng, [dims[m] for m in cs.b_modes], t_b)
    return cs, dims, A, B, (t_a, t_b)
